"""EmbeddingBag & friends — JAX has neither nn.EmbeddingBag nor CSR sparse,
so (per the assignment) the lookup layer IS part of the system:

  * `field_lookup`   — one id per field: jnp.take over a row-sharded table,
  * `embedding_bag`  — multi-hot bags: take + jax.ops.segment_sum (sum/mean),
  * `hash_ids`       — multiplicative hashing into per-field buckets, so any
                       raw id stream maps onto the fixed-size tables.

The big table carries the `model`-axis sharding (COIN's adjacency-slice
analogue — DESIGN.md §4): lookups over a row-sharded table lower to
all-to-all-style collectives exactly like the CE-partitioned aggregation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["embedding_bag", "field_lookup", "hash_ids"]

_HASH_MULT = jnp.uint32(2654435761)  # Knuth multiplicative


def hash_ids(raw_ids: jnp.ndarray, bucket_size: int, field_salt: jnp.ndarray | int = 0) -> jnp.ndarray:
    """Hash arbitrary int ids into [0, bucket_size) (quotient-free hashing)."""
    x = raw_ids.astype(jnp.uint32) + jnp.asarray(field_salt, jnp.uint32) * jnp.uint32(0x9E3779B9)
    x = x * _HASH_MULT
    x = x ^ (x >> 16)
    return (x % jnp.uint32(bucket_size)).astype(jnp.int32)


def field_lookup(table: jnp.ndarray, ids: jnp.ndarray, field_offsets: jnp.ndarray) -> jnp.ndarray:
    """ids: (B, F) per-field local ids → (B, F, D) embeddings.

    field_offsets: (F,) starting row of each field's sub-table inside the
    single concatenated table (one big table → one sharding spec).
    """
    flat = (ids + field_offsets[None, :]).reshape(-1)
    emb = jnp.take(table, flat, axis=0)
    return emb.reshape(ids.shape[0], ids.shape[1], table.shape[1])


def embedding_bag(
    table: jnp.ndarray,
    ids: jnp.ndarray,            # (nnz,) row ids
    segment_ids: jnp.ndarray,    # (nnz,) output bag per id
    num_bags: int,
    weights: jnp.ndarray | None = None,
    mode: str = "sum",
) -> jnp.ndarray:
    """torch.nn.EmbeddingBag equivalent: ragged gather + segment reduce."""
    rows = jnp.take(table, ids, axis=0)
    if weights is not None:
        rows = rows * weights[:, None]
    out = jax.ops.segment_sum(rows, segment_ids, num_segments=num_bags)
    if mode == "mean":
        cnt = jax.ops.segment_sum(
            jnp.ones_like(ids, dtype=rows.dtype), segment_ids, num_segments=num_bags
        )
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    elif mode != "sum":
        raise ValueError(mode)
    return out
