"""RecSys substrate: embedding tables, bags, and feature interactions."""

from repro.recsys.embedding import embedding_bag, field_lookup, hash_ids

__all__ = ["embedding_bag", "field_lookup", "hash_ids"]
