"""Serving substrate: continuous-batching scheduler over the KV cache."""

from repro.serve.scheduler import ContinuousBatcher, Request

__all__ = ["ContinuousBatcher", "Request"]
