"""Serving substrate: continuous-batching LM scheduler over the KV cache,
and online GCN query serving with the hot-neighbor cache (DESIGN.md §9)."""

from repro.serve.scheduler import ContinuousBatcher, Request
from repro.serve.graph import (
    GraphBatcher,
    GraphQuery,
    HotNeighborCache,
    ServeBlock,
    ServeSampler,
)

__all__ = [
    "ContinuousBatcher",
    "Request",
    "GraphBatcher",
    "GraphQuery",
    "HotNeighborCache",
    "ServeBlock",
    "ServeSampler",
]
