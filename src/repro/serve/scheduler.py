"""Continuous batching for KV-cache decode (the serving-loop substrate).

The decode step is compiled once for a FIXED batch of cache slots; requests
arrive/finish asynchronously. The scheduler owns the slot table:

  * admit: place a pending request in a free slot (its prompt tokens are
    teacher-forced through the same decode step — slot-local prefill, so one
    compiled program serves both phases),
  * step : one decode step for all active slots (idle slots run a masked
    no-op on slot 0's stream position),
  * retire: slots whose request hit max_tokens (or emitted EOS) free up.

The slot-position vector is per-slot, so the compiled step takes a (B,)
position array — `lm_decode_step` operates on a scalar position, so the
batcher drives the per-slot variant `decode_multi_pos` below (positions
differ across slots under continuous batching).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Request", "ContinuousBatcher", "decode_multi_pos"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (P,) int32
    max_new_tokens: int
    eos_id: int | None = None
    # runtime state
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


def decode_multi_pos(params, cache, tokens, positions, cfg, policy=None):
    """One decode step with PER-SLOT positions (continuous batching).

    tokens: (B,) int32; positions: (B,) int32. Built on the same layer math
    as `lm_decode_step`, with the cache update/mask indexed per slot.
    """
    from repro.dist.policy import NO_POLICY
    from repro.models.transformer_lm import _ffn
    from repro.nn.attention import rope
    from repro.nn.layers import rms_norm

    policy = policy or NO_POLICY
    B = tokens.shape[0]
    acfg = cfg.attn
    hd, Hk, G = acfg.head_dim, cfg.n_kv_heads, acfg.q_groups
    Smax = cache["k"].shape[2]
    x = params["embed"][tokens][:, None, :] * (cfg.d_model ** 0.5)
    windows = jnp.asarray(cfg.window_sizes())

    def layer(x, xs):
        lp, win, ck, cv = xs
        h = rms_norm(x, lp["ln1"])
        q = rope((h @ lp["attn"]["wq"]).reshape(B, 1, cfg.n_heads, hd), positions[:, None], acfg.rope_theta)
        k = rope((h @ lp["attn"]["wk"]).reshape(B, 1, Hk, hd), positions[:, None], acfg.rope_theta)
        v = (h @ lp["attn"]["wv"]).reshape(B, 1, Hk, hd)
        # per-slot scatter at its own position
        onehot = jax.nn.one_hot(positions, Smax, dtype=ck.dtype)        # (B, S)
        ck = ck * (1 - onehot[:, :, None, None]) + onehot[:, :, None, None] * k
        cv = cv * (1 - onehot[:, :, None, None]) + onehot[:, :, None, None] * v
        qg = q.reshape(B, Hk, G, hd) * (hd ** -0.5)
        s = jnp.einsum("bhgd,bshd->bhgs", qg, ck, preferred_element_type=jnp.float32)
        k_pos = jnp.arange(Smax)[None, :]
        valid = (k_pos <= positions[:, None]) & (k_pos > positions[:, None] - win)
        s = jnp.where(valid[:, None, None, :], s, -1e30)
        w = jax.nn.softmax(s, axis=-1)
        attn = jnp.einsum("bhgs,bshd->bhgd", w.astype(cv.dtype), cv).reshape(B, 1, cfg.n_heads * hd)
        x = x + attn @ lp["attn"]["wo"]
        h2 = rms_norm(x, lp["ln2"])
        f, _ = _ffn(lp, h2, cfg, policy)
        return x + f, (ck, cv)

    x, (nk, nv) = jax.lax.scan(
        layer, x, (params["layers"], windows, cache["k"], cache["v"]),
        unroll=cfg.scan_unroll,
    )
    x = rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x[:, 0] @ head).astype(jnp.float32)
    return logits, {"k": nk, "v": nv}


class ContinuousBatcher:
    def __init__(self, params, cfg, n_slots: int, max_len: int,
                 sampler: Callable[[np.ndarray], np.ndarray] | None = None):
        from repro.models.transformer_lm import lm_init_cache

        self.params, self.cfg = params, cfg
        self.n_slots, self.max_len = n_slots, max_len
        self.cache = lm_init_cache(cfg, n_slots, max_len)
        self.positions = np.zeros(n_slots, np.int32)
        self.slot_req: list[Request | None] = [None] * n_slots
        self.pending: list[Request] = []
        self.finished: list[Request] = []
        self.next_token = np.zeros(n_slots, np.int32)
        self._prefill_left: list[int] = [0] * n_slots
        self.sampler = sampler or (lambda logits: np.argmax(logits, axis=-1))
        self._step = jax.jit(decode_multi_pos, static_argnames=("cfg",))
        self.steps_run = 0

    # --------------------------------------------------------------- control
    def submit(self, req: Request) -> None:
        assert len(req.prompt) + req.max_new_tokens <= self.max_len
        self.pending.append(req)

    def _admit(self) -> None:
        for slot in range(self.n_slots):
            if self.slot_req[slot] is None and self.pending:
                req = self.pending.pop(0)
                self.slot_req[slot] = req
                self.positions[slot] = 0
                self.next_token[slot] = req.prompt[0]
                self._prefill_left[slot] = len(req.prompt) - 1

    @property
    def active(self) -> int:
        return sum(r is not None for r in self.slot_req)

    def step(self) -> None:
        """One engine iteration: admit → decode all slots → sample/retire →
        re-admit (a slot retired this step is refilled before the step ends,
        so the next decode runs at full occupancy)."""
        self._admit()
        if self.active == 0:
            return
        logits, self.cache = self._step(
            self.params, self.cache,
            jnp.asarray(self.next_token), jnp.asarray(self.positions), self.cfg,
        )
        self.steps_run += 1
        logits = np.asarray(logits)
        sampled = self.sampler(logits)
        retired = False
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            pos = int(self.positions[slot])
            if self._prefill_left[slot] > 0:
                # teacher-forced prefill: feed the next prompt token
                idx = len(req.prompt) - self._prefill_left[slot]
                self.next_token[slot] = req.prompt[idx]
                self._prefill_left[slot] -= 1
            else:
                tok = int(sampled[slot])
                req.generated.append(tok)
                self.next_token[slot] = tok
                # Retire on budget, EOS (including one emitted on the very
                # first decode step), or cache exhaustion. The cache bound is
                # `pos + 2 > max_len`: the next decode would write position
                # pos+1, and pos+1 == max_len−1 is still a legal row — the
                # old `>=` retired such a request one token early.
                if (
                    len(req.generated) >= req.max_new_tokens
                    or (req.eos_id is not None and tok == req.eos_id)
                    or pos + 2 > self.max_len
                ):
                    req.done = True
                    self.finished.append(req)
                    self.slot_req[slot] = None
                    retired = True
                    continue
            self.positions[slot] = pos + 1
        if retired and self.pending:
            self._admit()

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        for _ in range(max_steps):
            if not self.pending and self.active == 0:
                break
            self.step()
        return self.finished
