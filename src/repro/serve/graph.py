"""Online GCN query serving with a communication-aware hot-neighbor cache.

This is the graph analogue of `repro.serve.scheduler` (DESIGN.md §9): node
classification queries arrive asynchronously, a :class:`GraphBatcher` packs
them into fixed-shape micro-batches, and ONE jitted forward serves every
micro-batch — the slot discipline of `ContinuousBatcher` applied to sampled
subgraphs instead of KV-cache slots.

The COIN claim (PAPER.md §IV) is that GCN cost is communication: the same hub
rows are gathered over and over. Serving makes that literal — every query on
a node adjacent to a hub re-fetches and re-computes the hub's L-hop
neighborhood. The **hot-neighbor cache** (:class:`HotNeighborCache`) is a
degree-ranked, capacity-bounded store of layer-ℓ activations for hub nodes;
sampled subgraphs *truncate* at cached frontier nodes and the jitted forward
injects the stored row, so the hub's neighborhood is never re-gathered.

Exactness contract (what makes cached rows reusable at all):

* :class:`ServeSampler` draws each node's fanout in-neighborhood with a
  counter-based hash of ``(node, slot, seed)`` — N̂(v) is a pure function of
  v, not of the query or micro-batch. Every block that expands v sees the
  same subtree, so the layer-ℓ activation of v computed in any block is a
  pure function of (v, params, features).
* Edge weights are full-graph symmetric normalization (1/√d̂(u)·1/√d̂(v)) —
  per-node-pair, block-independent.
* Serving runs fp32: per-tensor fake-quant calibration ranges depend on the
  whole block's activations (`repro.core.quant.fake_quant`), which would
  break per-node purity, so the engine force-disables quantization.

Under that contract cache-on and cache-off produce identical logits (fp32
tolerance) while cache-on samples strictly fewer nodes and edges per query —
pinned by `tests/test_serve_graph.py`, reported by `repro.launch.serve` and
`benchmarks/serve_bench.py`.

Batch packing is partition-aligned (`repro.core.partition`): pending queries
are grouped by the part owning their seed so a multi-device deployment — one
part per device, `ShardingPolicy` comm contract of DESIGN.md §7/§8 — sees
micro-batches whose subgraphs stay inside one part and ship minimal halo
rows. The batcher records the foreign-row count per micro-batch the same way
PR 2's dry-run records `exchange` rows.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dataflow import choose_order
from repro.core.partition import Partition
from repro.dist.policy import NO_POLICY, ShardingPolicy
from repro.graph.ops import aggregate_padded
from repro.graph.structure import GraphData
from repro.models.gcn import GCNConfig
from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _obs_trace

__all__ = [
    "GraphQuery",
    "GraphBatcher",
    "HotNeighborCache",
    "ServeSampler",
    "ServeBlock",
    "hot_query_stream",
]


def hot_query_stream(graph: GraphData, n: int, seed: int = 1) -> np.ndarray:
    """``n`` degree-weighted query nodes — the hub-heavy access pattern GCN
    serving sees in the wild (hubs are queried, and neighbored, most). The
    CLI, benchmark, example, and tests all draw from this one stream."""
    rng = np.random.default_rng(seed)
    deg = np.bincount(graph.edge_index[1], minlength=graph.n_nodes).astype(np.float64) + 1.0
    return rng.choice(graph.n_nodes, size=n, p=deg / deg.sum())


# --------------------------------------------------------------------- hashing
_U64 = np.uint64
_MIX1 = _U64(0xBF58476D1CE4E5B9)
_MIX2 = _U64(0x94D049BB133111EB)
_GOLDEN = _U64(0x9E3779B97F4A7C15)


def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer — a vectorized counter-based hash (no RNG state,
    so a node's draws are reproducible from its id alone)."""
    with np.errstate(over="ignore"):
        z = x.astype(np.uint64) + _GOLDEN
        z = (z ^ (z >> _U64(30))) * _MIX1
        z = (z ^ (z >> _U64(27))) * _MIX2
        return z ^ (z >> _U64(31))


# --------------------------------------------------------------------- sampler
@dataclasses.dataclass
class ServeBlock:
    """One packed serving micro-batch (static shapes, ghost-padded)."""

    node_ids: np.ndarray        # (max_nodes,) original ids; -1 at padding
    senders: np.ndarray         # (max_edges,) local ids; max_nodes at padding
    receivers: np.ndarray       # (max_edges,) local ids; max_nodes at padding
    edge_weight: np.ndarray     # (max_edges,) float32; 0 at padding
    n_seeds: int
    n_nodes: int
    n_edges: int
    max_nodes: int
    max_edges: int
    # layer → rows to overwrite after that layer: (mask (max_nodes,), pairs)
    inject: dict[int, list[tuple[int, int]]]   # layer -> [(local, node), ...]
    harvest: list[tuple[int, int, int]]        # (layer, local, node)
    cache_hits: int
    cache_misses: int


class ServeSampler:
    """Deterministic fanout sampler: N̂(v) is a pure function of (v, seed).

    Unlike the training `NeighborSampler` (fresh RNG draws per batch), every
    expansion of node v — any query, any micro-batch — yields the same
    in-neighborhood, which is exactly what makes v's layer-ℓ activation
    cacheable. A single scalar fanout applies at every depth so the
    neighborhood does not depend on the depth v was reached at.
    """

    def __init__(self, graph: GraphData, fanout: int, n_layers: int, seed: int = 0):
        self.fanout = int(fanout)
        self.n_layers = int(n_layers)
        self.n_nodes = graph.n_nodes
        self.seed = _U64(seed)
        s = graph.edge_index[0].astype(np.int64)
        r = graph.edge_index[1].astype(np.int64)
        order = np.argsort(r, kind="stable")
        self._nbr = s[order]
        self._indptr = np.zeros(graph.n_nodes + 1, np.int64)
        np.add.at(self._indptr, r + 1, 1)
        np.cumsum(self._indptr, out=self._indptr)
        self.in_deg = (self._indptr[1:] - self._indptr[:-1]).astype(np.int64)
        out_deg = np.bincount(s, minlength=graph.n_nodes).astype(np.float64)
        # Full-graph sym normalization — per-node scalars, block-independent.
        self._inv_r = (1.0 / np.sqrt(np.maximum(self.in_deg, 1.0))).astype(np.float32)
        self._inv_s = (1.0 / np.sqrt(np.maximum(out_deg, 1.0))).astype(np.float32)

    def max_shapes(self, batch_seeds: int) -> tuple[int, int]:
        """Static (max_nodes, max_edges) for a micro-batch of seed queries."""
        nodes, edges, width = 1, 0, 1
        for _ in range(self.n_layers):
            width *= self.fanout
            nodes += width
            edges += width
        return batch_seeds * nodes, batch_seeds * edges

    def subtree_counts(self, layer: int) -> tuple[int, int]:
        """Worst-case (nodes, edges) a truncation at ``layer`` avoids — the
        bytes-saved formula of DESIGN.md §9.3."""
        nodes = sum(self.fanout ** i for i in range(1, layer + 1))
        return nodes, nodes

    def neighbors(self, nodes: np.ndarray) -> np.ndarray:
        """(len(nodes), fanout) deterministic in-neighbor draws (with
        replacement); zero-in-degree nodes emit self-messages."""
        nodes = np.asarray(nodes, dtype=np.int64)
        deg = self.in_deg[nodes]
        slots = np.arange(self.fanout, dtype=np.uint64)
        with np.errstate(over="ignore"):
            key = nodes.astype(np.uint64)[:, None] * _MIX1 + slots[None, :] + self.seed * _MIX2
        pick = (_mix64(key) % np.maximum(deg, 1).astype(np.uint64)[:, None]).astype(np.int64)
        if self._nbr.size:
            src = self._nbr[np.minimum(self._indptr[nodes][:, None] + pick, self._nbr.size - 1)]
        else:
            src = np.broadcast_to(nodes[:, None], (nodes.shape[0], self.fanout)).copy()
        return np.where((deg > 0)[:, None], src, nodes[:, None])

    def edge_w(self, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
        return self._inv_s[src] * self._inv_r[dst]

    def sample_block(
        self,
        seeds: np.ndarray,
        batch_seeds: int,
        cache: "HotNeighborCache | None" = None,
    ) -> ServeBlock:
        """Expand the seeds' L-hop trees, truncating at cached frontiers.

        Correctness hinges on tracking *(node, layer)* requirements, not just
        nodes: the merged-block forward runs every edge at every layer, so an
        edge c→v makes layer-j of v read layer-(j−1) of c for EVERY j at
        which v itself must be valid (self-loops alone force a seed to be
        valid at every layer). Each requirement is satisfied either by the
        cache (record an injection, stop) or by expanding the node once and
        propagating the (child, layer−1) requirements. Layer-0 requirements
        are raw features — always valid. Without a cache this reduces to the
        plain BFS tree; with one, blocks only shrink.
        """
        max_nodes, max_edges = self.max_shapes(batch_seeds)
        seeds = np.asarray(seeds, dtype=np.int64)
        local: dict[int, int] = {}
        node_list: list[int] = []

        def loc(v: int) -> int:
            i = local.get(v)
            if i is None:
                i = local[v] = len(node_list)
                node_list.append(v)
            return i

        for v in seeds:
            loc(int(v))
        edge_src: list[np.ndarray] = []
        edge_dst: list[np.ndarray] = []
        expanded: dict[int, np.ndarray] = {}        # node -> its N̂ row
        inject: dict[int, list[tuple[int, int]]] = {}
        harvest: list[tuple[int, int, int]] = []
        hits = misses = 0
        L = self.n_layers
        # need[layer] is insertion-ordered (dict keys) for deterministic
        # expansion order; a (node, layer) pair is processed at most once.
        need: dict[int, dict[int, None]] = {L: dict.fromkeys(int(v) for v in seeds)}
        for layer in range(L, 0, -1):
            todo = list(need.get(layer, ()))
            if not todo:
                continue
            expand_list: list[int] = []
            for v in todo:
                if cache is not None and layer < L:
                    val = cache.lookup(v, layer)
                    if val is not None:
                        hits += 1
                        inject.setdefault(layer, []).append((loc(v), v))
                        continue
                    misses += 1
                expand_list.append(v)
            fresh = [v for v in expand_list if v not in expanded]
            if fresh:
                rows = self.neighbors(np.asarray(fresh, dtype=np.int64))
                for v, row in zip(fresh, rows):
                    expanded[v] = row
                    for c in row:
                        loc(int(c))
                    edge_src.append(row)
                    edge_dst.append(np.full(self.fanout, v, np.int64))
            for v in expand_list:
                if cache is not None and layer <= L - 1:
                    harvest.append((layer, local[v], v))
                if layer - 1 >= 1:
                    nxt = need.setdefault(layer - 1, {})
                    for c in expanded[v]:
                        nxt.setdefault(int(c), None)
        src = np.concatenate(edge_src) if edge_src else np.zeros(0, np.int64)
        dst = np.concatenate(edge_dst) if edge_dst else np.zeros(0, np.int64)
        n_nodes, n_edges = len(node_list), src.shape[0]
        assert n_nodes <= max_nodes and n_edges <= max_edges
        lut = {v: i for i, v in enumerate(node_list)}
        node_ids = np.full(max_nodes, -1, np.int64)
        node_ids[:n_nodes] = node_list
        senders = np.full(max_edges, max_nodes, np.int32)
        receivers = np.full(max_edges, max_nodes, np.int32)
        edge_weight = np.zeros(max_edges, np.float32)
        if n_edges:
            senders[:n_edges] = [lut[int(v)] for v in src]
            receivers[:n_edges] = [lut[int(v)] for v in dst]
            edge_weight[:n_edges] = self.edge_w(src, dst)
        return ServeBlock(
            node_ids=node_ids,
            senders=senders,
            receivers=receivers,
            edge_weight=edge_weight,
            n_seeds=len(seeds),
            n_nodes=n_nodes,
            n_edges=n_edges,
            max_nodes=max_nodes,
            max_edges=max_edges,
            inject=inject,
            harvest=harvest,
            cache_hits=hits,
            cache_misses=misses,
        )


# ----------------------------------------------------------------------- cache
class HotNeighborCache:
    """Degree-ranked, capacity-bounded store of layer-ℓ hub activations.

    Admission is by in-degree (COIN's hubs — I-GCN's "islands" — are exactly
    the rows every query re-fetches): when full, a newcomer evicts the
    lowest-degree resident only if it out-ranks it. ``invalidate`` drops
    every entry — the engine calls it on any weight update, since stored
    activations are pure functions of (params, features). Graph/feature
    deltas use :meth:`invalidate_nodes` instead: the engine computes which
    residents' sampled dependency cones intersect the delta
    (``GraphBatcher._scoped_invalidate``) and drops only those.
    """

    def __init__(self, capacity: int, degree: np.ndarray):
        self.capacity = int(capacity)
        self.degree = np.asarray(degree)
        self._entries: dict[int, dict[int, np.ndarray]] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0
        self.scoped_invalidations = 0
        self.nodes_dropped = 0
        self.rows_saved = 0
        self.edges_saved = 0
        self.bytes_saved = 0.0

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def peek(self, node: int, layer: int) -> np.ndarray | None:
        """Non-counting read. Engine-internal re-reads of an entry the
        sampler already counted — the injection copy in
        ``GraphBatcher.step`` — go through here so ``stats()["hits"]``
        counts each serving hit exactly once."""
        e = self._entries.get(node)
        if e is None:
            return None
        return e.get(layer)

    def lookup(self, node: int, layer: int) -> np.ndarray | None:
        """Counting read: exactly one hit or miss per call, tallied HERE and
        nowhere else (the batcher must not re-add per-block counts on top —
        that double-counting inflated ``hit_rate``)."""
        val = self.peek(node, layer)
        if val is None:
            self.misses += 1
        else:
            self.hits += 1
        return val

    def admit(self, node: int, layer: int, value: np.ndarray) -> bool:
        e = self._entries.get(node)
        if e is not None:
            e[layer] = value
            return True
        if len(self._entries) < self.capacity:
            self._entries[node] = {layer: value}
            return True
        victim = min(self._entries, key=lambda v: self.degree[v])
        if self.degree[node] <= self.degree[victim]:
            return False
        del self._entries[victim]
        self.evictions += 1
        self._entries[node] = {layer: value}
        return True

    def invalidate(self, reason: str = "") -> None:
        self._entries.clear()
        self.invalidations += 1

    def invalidate_nodes(self, nodes) -> int:
        """Scoped drop: remove only the named residents (all their layers).
        Returns how many residents were actually dropped."""
        dropped = 0
        for v in nodes:
            if self._entries.pop(int(v), None) is not None:
                dropped += 1
        self.scoped_invalidations += 1
        self.nodes_dropped += dropped
        return dropped

    def resident_depths(self) -> dict[int, int]:
        """{node: deepest cached layer} — what a scoped invalidation must
        walk (an entry cached at layer ℓ depends on the ℓ-hop sampled cone)."""
        return {v: max(layers) for v, layers in self._entries.items() if layers}

    def record_saving(self, rows: int, edges: int, bytes_: float) -> None:
        self.rows_saved += rows
        self.edges_saved += edges
        self.bytes_saved += bytes_

    def stats(self) -> dict[str, Any]:
        return {
            "resident": len(self._entries),
            "capacity": self.capacity,
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hit_rate,
            "evictions": self.evictions,
            "invalidations": self.invalidations,
            "scoped_invalidations": self.scoped_invalidations,
            "nodes_dropped": self.nodes_dropped,
            "rows_saved": self.rows_saved,
            "edges_saved": self.edges_saved,
            "bytes_saved": self.bytes_saved,
        }


# --------------------------------------------------------------------- queries
@dataclasses.dataclass
class GraphQuery:
    """One node-classification query plus its serving outcome."""

    qid: int
    node: int
    t_submit: float = 0.0
    logits: np.ndarray | None = None
    latency_s: float | None = None
    micro_batch: int | None = None


# --------------------------------------------------------------------- batcher
class GraphBatcher:
    """Admit node queries, pack fixed-shape micro-batches, serve them through
    one compiled forward (GCN with activation injection; PNA/EGNN plain).

    ``model``: "gcn" (hot-neighbor cache supported), "pna", or "egnn".
    ``cache_capacity`` > 0 enables the cache (GCN only). ``partition`` turns
    on partition-aligned packing. Static shapes come from
    ``(batch_seeds, fanout, n_layers)`` so every micro-batch — whatever its
    live query count — replays the same compiled program.
    """

    def __init__(
        self,
        params: dict,
        graph: GraphData,
        cfg: Any,
        *,
        model: str = "gcn",
        batch_seeds: int = 8,
        fanout: int = 5,
        cache_capacity: int = 0,
        partition: Partition | None = None,
        policy: ShardingPolicy = NO_POLICY,
        seed: int = 0,
        add_self_loops: bool = True,
    ):
        if model not in ("gcn", "pna", "egnn"):
            raise ValueError(f"unknown serve model {model!r}")
        if model != "gcn" and cache_capacity:
            raise ValueError("the hot-neighbor cache needs per-layer injection "
                             "hooks; only the GCN serve forward has them")
        self.model = model
        self.params = params
        self.policy = policy
        self.partition = partition
        self.batch_seeds = int(batch_seeds)
        if model == "gcn":
            assert isinstance(cfg, GCNConfig)
            if cfg.quant.enabled:
                # Per-tensor calibration ranges are batch-dependent; serving
                # must keep per-node purity (module docstring), so fp32 only.
                cfg = dataclasses.replace(cfg, quant=cfg.quant.replace(enabled=False))
            n_layers = cfg.n_layers
        else:
            n_layers = cfg.n_layers
        self.cfg = cfg
        assert graph.features is not None, "serving needs node features"
        self.features = np.asarray(graph.features, np.float32)
        self.positions = (
            np.asarray(graph.positions, np.float32) if graph.positions is not None else None
        )
        if model == "egnn" and self.positions is None:
            raise ValueError("egnn serving needs graph.positions")
        self.graph = graph
        self._add_self_loops = bool(add_self_loops)
        self._seed = int(seed)
        g = graph.with_self_loops() if add_self_loops else graph
        self.sampler = ServeSampler(g, fanout, n_layers, seed=seed)
        self.max_nodes, self.max_edges = self.sampler.max_shapes(self.batch_seeds)
        self.cache = (
            HotNeighborCache(cache_capacity, self.sampler.in_deg) if cache_capacity else None
        )
        # Injectable layers 1..L−1 with their widths (GCN only; empty else).
        if model == "gcn":
            self._inject_dims = {i + 1: cfg.layer_dims[i + 1] for i in range(cfg.n_layers - 1)}
        else:
            self._inject_dims = {}
        self.pending: list[GraphQuery] = []
        self.finished: list[GraphQuery] = []
        self._next_qid = 0
        self.micro_batches = 0
        self.traces = 0
        self.nodes_sampled = 0
        self.edges_sampled = 0
        self.queries_served = 0
        self.foreign_rows = 0
        self._fwd = jax.jit(self._build_forward())

    # ------------------------------------------------------------ forward pass
    def _build_forward(self):
        cfg, policy, model = self.cfg, self.policy, self.model
        n = self.max_nodes
        n_edges = self.max_edges

        if model == "gcn":
            layers = sorted(self._inject_dims)

            def fwd(params, x, senders, receivers, edge_weight, masks, vals):
                self.traces += 1            # runs once per trace, not per call
                h = x
                inter = []
                for i in range(cfg.n_layers):
                    w = params[f"w{i}"]
                    d_in, d_out = w.shape
                    order = cfg.dataflow
                    if order == "auto":
                        order = choose_order(n, d_in, d_out, n_edges=n_edges)
                    if order == "feature_first":
                        z = h @ w
                        z = policy.constrain(z, "node_hidden")
                        h = aggregate_padded(z, senders, receivers, n, edge_weight)
                    else:
                        z = aggregate_padded(h, senders, receivers, n, edge_weight)
                        z = policy.constrain(z, "node_hidden")
                        h = z @ w
                    h = h + params[f"b{i}"]
                    if i < cfg.n_layers - 1:
                        h = jax.nn.relu(h)
                    layer = i + 1
                    if layer in self._inject_dims:
                        j = layers.index(layer)
                        h = jnp.where(masks[j][:, None] > 0, vals[j], h)
                        inter.append(h)
                    h = policy.constrain(h, "node_hidden")
                return h, tuple(inter)

            return fwd

        if model == "pna":
            from repro.models.pna import pna_forward

            def fwd(params, x, senders, receivers, edge_weight, masks, vals):
                self.traces += 1
                edge_mask = (edge_weight > 0).astype(x.dtype)
                return pna_forward(params, x, senders, receivers, cfg, policy,
                                   edge_mask=edge_mask), ()

            return fwd

        from repro.models.egnn import egnn_forward

        def fwd(params, xh, senders, receivers, edge_weight, masks, vals):
            self.traces += 1
            edge_mask = (edge_weight > 0).astype(xh.dtype)
            pos, feats = xh[:, :3], xh[:, 3:]
            out, _ = egnn_forward(params, feats, pos, senders, receivers, cfg,
                                  policy, edge_mask=edge_mask)
            return out, ()

        return fwd

    # --------------------------------------------------------------- admission
    def submit(self, node: int, qid: int | None = None) -> GraphQuery:
        q = GraphQuery(
            qid=self._next_qid if qid is None else qid,
            node=int(node),
            t_submit=time.perf_counter(),
        )
        self._next_qid += 1
        self.pending.append(q)
        return q

    def _pick_batch(self) -> list[GraphQuery]:
        """Partition-aligned packing: drain the part with the most pending
        queries first (FIFO within a part; FIFO overall without a partition),
        topping up from the next-largest parts when it underfills."""
        if not self.pending:
            return []
        if self.partition is None:
            take = self.pending[: self.batch_seeds]
            self.pending = self.pending[self.batch_seeds:]
            return take
        by_part: dict[int, list[GraphQuery]] = {}
        for q in self.pending:
            by_part.setdefault(int(self.partition.assignment[q.node]), []).append(q)
        order = sorted(by_part, key=lambda p: (-len(by_part[p]), p))
        take: list[GraphQuery] = []
        for p in order:
            room = self.batch_seeds - len(take)
            if room <= 0:
                break
            take.extend(by_part[p][:room])
        chosen = set(id(q) for q in take)
        self.pending = [q for q in self.pending if id(q) not in chosen]
        return take

    # ------------------------------------------------------------------- serve
    def step(self) -> list[GraphQuery]:
        """One engine iteration: pick → sample/truncate → forward → harvest."""
        queries = self._pick_batch()
        if not queries:
            return []
        if _obs_metrics.enabled():
            t_pick = time.perf_counter()
            for q in queries:
                _obs_metrics.observe("serve.queue_wait_ms",
                                     (t_pick - q.t_submit) * 1e3)
            _obs_metrics.observe("serve.batch_occupancy",
                                 len(queries) / max(self.batch_seeds, 1))
        seeds: list[int] = []
        row_of: dict[int, int] = {}
        for q in queries:
            if q.node not in row_of:
                row_of[q.node] = len(seeds)
                seeds.append(q.node)
        with _obs_trace.span("serve.sample", args={"seeds": len(seeds)}):
            blk = self.sampler.sample_block(
                np.asarray(seeds), self.batch_seeds, self.cache)
        x = np.zeros((self.max_nodes, self.features.shape[1]), np.float32)
        valid = blk.node_ids[: blk.n_nodes]
        x[: blk.n_nodes] = self.features[valid]
        if self.model == "egnn":
            pos = np.zeros((self.max_nodes, 3), np.float32)
            pos[: blk.n_nodes] = self.positions[valid]
            x = np.concatenate([pos, x], axis=1)
        layers = sorted(self._inject_dims)
        masks, vals = [], []
        for layer in layers:
            m = np.zeros(self.max_nodes, np.float32)
            v = np.zeros((self.max_nodes, self._inject_dims[layer]), np.float32)
            for lc, node in blk.inject.get(layer, []):
                m[lc] = 1.0
                # peek, not lookup: the sampler already counted this hit.
                v[lc] = self.cache.peek(node, layer)
            masks.append(jnp.asarray(m))
            vals.append(jnp.asarray(v))
        with _obs_trace.span("serve.forward",
                             args={"nodes": int(blk.n_nodes)}) as _sp:
            out, inter = self._fwd(
                self.params,
                jnp.asarray(x),
                jnp.asarray(blk.senders),
                jnp.asarray(blk.receivers),
                jnp.asarray(blk.edge_weight),
                tuple(masks),
                tuple(vals),
            )
            _sp.sync = out
        out = np.asarray(out)
        now = time.perf_counter()
        for q in queries:
            q.logits = out[row_of[q.node]]
            q.latency_s = now - q.t_submit
            q.micro_batch = self.micro_batches
            if _obs_metrics.enabled():
                _obs_metrics.observe("serve.latency_ms", q.latency_s * 1e3)
        self.finished.extend(queries)
        # Harvest hub activations (degree-ranked admission) for future hits.
        if self.cache is not None:
            inter = [np.asarray(a) for a in inter]
            for layer, lc, node in blk.harvest:
                self.cache.admit(node, layer, inter[layers.index(layer)][lc].copy())
            # Hits/misses were tallied by cache.lookup during sampling —
            # re-adding blk.cache_hits here would count every hit twice.
            feat_bytes = self.features.dtype.itemsize * self.features.shape[1]
            for layer, pairs in blk.inject.items():
                rows, edges = self.sampler.subtree_counts(layer)
                for _lc, node in pairs:
                    row = self.cache.peek(node, layer)
                    inj_bytes = (
                        row.nbytes if row is not None
                        else self.features.dtype.itemsize * self._inject_dims[layer]
                    )
                    self.cache.record_saving(rows, edges, rows * feat_bytes - inj_bytes)
        if self.partition is not None:
            parts = self.partition.assignment[valid]
            major = int(self.partition.assignment[seeds[0]])
            self.foreign_rows += int((parts != major).sum())
        self.micro_batches += 1
        self.queries_served += len(queries)
        self.nodes_sampled += blk.n_nodes
        self.edges_sampled += blk.n_edges
        if _obs_metrics.enabled():
            _obs_metrics.inc("serve.queries", float(len(queries)))
            _obs_metrics.inc("serve.micro_batches")
            if self.cache is not None:
                _obs_metrics.set_gauge("serve.cache_hit_rate",
                                       self.cache.hit_rate)
        return queries

    def run_until_drained(self, max_batches: int = 10_000) -> list[GraphQuery]:
        for _ in range(max_batches):
            if not self.pending:
                break
            self.step()
        return self.finished

    # ----------------------------------------------------------------- updates
    def update_params(self, params: dict) -> None:
        """Swap model weights; cached activations are stale → invalidate."""
        self.params = params
        if self.cache is not None:
            self.cache.invalidate("weights")

    def update_features(self, features: np.ndarray, touched=None) -> None:
        """Swap node features. Without ``touched`` every cached activation
        is presumed stale (full invalidation, the pre-delta behavior). With
        ``touched`` — the rows that actually changed — only residents whose
        sampled dependency cone reaches a touched row are dropped."""
        assert features.shape == self.features.shape
        self.features = np.asarray(features, np.float32)
        self.graph = dataclasses.replace(self.graph, features=self.features)
        if self.cache is None:
            return
        if touched is None:
            self.cache.invalidate("features")
        else:
            self._scoped_invalidate(
                set(np.asarray(touched, np.int64).ravel().tolist()))

    def apply_graph_delta(self, delta) -> dict[str, Any]:
        """Mutate the served graph in place: apply the
        `repro.dist.delta.GraphDelta` (order-preserving — deletes compact,
        inserts append), rebuild the sampler over the new edges, and drop
        ONLY the cache residents whose sampled dependency cone intersects
        the delta.

        Why the scoped drop is exact and not heuristic: the new sampler's
        frontier walk from a resident v covers, by induction over layers,
        every node v's cached activation could read under the NEW graph. If
        that cone avoids every delta endpoint and feature touch, the old and
        new sampled subtrees are identical node-for-node (order-preserving
        application keeps untouched CSR rows — and so the counter-hashed
        draws — bit-identical, and the sym-norm scalars of unchanged-degree
        nodes are unchanged), so the cached value is still the exact
        activation. Degree-changed nodes are delta endpoints themselves and
        thus inside every dependent cone. Fixed-shape compiled forwards are
        untouched — ``max_shapes`` depends only on (fanout, n_layers)."""
        from repro.dist.delta import apply_delta_to_graph

        self.graph = apply_delta_to_graph(self.graph, delta)
        if self.graph.features is not None:
            self.features = np.asarray(self.graph.features, np.float32)
        g = (self.graph.with_self_loops() if self._add_self_loops
             else self.graph)
        self.sampler = ServeSampler(
            g, self.sampler.fanout, self.sampler.n_layers, seed=self._seed)
        dropped = resident = 0
        if self.cache is not None:
            resident = len(self.cache)
            self.cache.degree = self.sampler.in_deg
            dropped = self._scoped_invalidate(
                set(delta.touched_nodes().tolist()))
        return {"affected_seeds": int(delta.touched_nodes().size),
                "residents_before": resident, "residents_dropped": dropped}

    def adopt_partition(self, partition: Partition | None) -> None:
        """Swap the partition that drives batch packing — the serve side of
        an online re-localization (`repro.dist.delta.DeltaPlanner.relocalize`).

        NO cache or sampler invalidation is needed, by construction: the
        `HotNeighborCache` is keyed by ORIGINAL node ids and the
        `ServeSampler`'s counter-hashed draws are a pure function of
        ``(node, seed)`` — neither ever sees the planner's row order, so a
        new node→CE map changes only which pending queries pack together
        (``_pick_batch``) and the ``foreign_rows`` accounting. Graph
        MUTATIONS are the separate path (:meth:`apply_graph_delta`, which
        does run the scoped invalidation); a re-localization mutates no
        edge. The cache-on == cache-off equivalence across a relocalize is
        pinned by ``tests/test_relocalize.py``."""
        if partition is not None and int(partition.n_nodes) != self.graph.n_nodes:
            raise ValueError(
                f"partition covers {partition.n_nodes} nodes, graph has "
                f"{self.graph.n_nodes}")
        self.partition = partition

    def _scoped_invalidate(self, affected: set[int]) -> int:
        """Drop cache residents whose L-hop sampled cone (under the CURRENT
        sampler, L = the entry's deepest cached layer) intersects
        ``affected``. Exact frontier walk, not a radius heuristic."""
        if self.cache is None or not affected:
            return 0
        hot = np.zeros(self.sampler.n_nodes, bool)
        hot[np.fromiter(affected, np.int64, len(affected))] = True
        stale: list[int] = []
        for v, depth in self.cache.resident_depths().items():
            if hot[v]:
                stale.append(v)
                continue
            frontier = np.asarray([v], np.int64)
            for _ in range(depth):
                frontier = np.unique(self.sampler.neighbors(frontier))
                if hot[frontier].any():
                    stale.append(v)
                    break
        return self.cache.invalidate_nodes(stale)

    # ------------------------------------------------------------- accounting
    def stats(self) -> dict[str, Any]:
        lat = sorted(q.latency_s for q in self.finished if q.latency_s is not None)

        def pct(p: float) -> float:
            return lat[min(int(p * len(lat)), len(lat) - 1)] if lat else 0.0

        out: dict[str, Any] = {
            "queries": self.queries_served,
            "micro_batches": self.micro_batches,
            "traces": self.traces,
            "nodes_per_query": self.nodes_sampled / max(self.queries_served, 1),
            "edges_per_query": self.edges_sampled / max(self.queries_served, 1),
            "p50_ms": pct(0.50) * 1e3,
            "p99_ms": pct(0.99) * 1e3,
            "foreign_rows": self.foreign_rows,
        }
        if self.cache is not None:
            out["cache"] = self.cache.stats()
        return out

    def export_metrics(self) -> dict[str, Any]:
        """Mirror :meth:`stats` into the obs registry and return it.

        The gauges carry the ENGINE's exact accounting (the sorted-latency
        p50/p99, the cache's own hit counters) — not re-derivations — so an
        exported snapshot equals ``stats()`` value for value; the obs-side
        ``serve.latency_ms`` histogram percentiles are the bucketed
        approximation of the same samples (both pinned in
        `tests/test_obs_integration.py`). No-op (still returns the stats)
        when metrics are disabled."""
        out = self.stats()
        if _obs_metrics.enabled():
            _obs_metrics.set_gauge("serve.p50_ms", out["p50_ms"])
            _obs_metrics.set_gauge("serve.p99_ms", out["p99_ms"])
            _obs_metrics.set_gauge("serve.nodes_per_query", out["nodes_per_query"])
            _obs_metrics.set_gauge("serve.edges_per_query", out["edges_per_query"])
            _obs_metrics.set_gauge("serve.foreign_rows", out["foreign_rows"])
            cache = out.get("cache")
            if cache is not None:
                _obs_metrics.set_gauge("serve.cache_hit_rate", cache["hit_rate"])
                _obs_metrics.set_gauge("serve.cache_resident", cache["resident"])
        return out
