#!/usr/bin/env python
"""Docs reference check: README.md / DESIGN.md must cite only real files.

Scans the two architecture docs for file-like tokens (anything ending in a
code extension) and fails if a referenced file cannot be found in the repo.
Bare names and package-relative paths are resolved against a small set of
candidate roots (repo root, src/repro, benchmarks, examples, tests, tools),
matching how the docs abbreviate paths (`train/elastic.py` ==
`src/repro/train/elastic.py`). Paths under generated directories
(results/) are exempt: they legitimately do not exist in a fresh checkout.

    python tools/check_docs_refs.py
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
DOCS = ("README.md", "DESIGN.md")
GENERATED = ("results/",)
CANDIDATE_ROOTS = ("", "src/repro", "benchmarks", "examples", "tests", "tools")
TOKEN = re.compile(r"[\w.\-/]+\.(?:py|md|yml|yaml|toml|txt|json)\b")


def resolves(token: str) -> bool:
    while token.startswith("./"):
        token = token[2:]
    for root in CANDIDATE_ROOTS:
        if (ROOT / root / token).exists():
            return True
    return False


def main() -> int:
    missing: list[tuple[str, str]] = []
    for doc in DOCS:
        text = (ROOT / doc).read_text(encoding="utf-8")
        for tok in sorted({m.group(0) for m in TOKEN.finditer(text)}):
            if tok.startswith(GENERATED):
                continue
            if not resolves(tok):
                missing.append((doc, tok))
    if missing:
        for doc, tok in missing:
            print(f"MISSING: {doc} references {tok!r} which does not exist")
        return 1
    print(f"docs refs OK ({', '.join(DOCS)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
