#!/usr/bin/env python
"""Docs reference check: the architecture docs must cite only real things.

Two passes over README.md, DESIGN.md, and every ``docs/*.md``:

1. **File references** — file-like tokens (anything ending in a code
   extension) must resolve somewhere in the repo. Bare names and
   package-relative paths are resolved against a small set of candidate
   roots (repo root, src/repro, docs, benchmarks, examples, tests, tools),
   matching how the docs abbreviate paths (`train/elastic.py` ==
   `src/repro/train/elastic.py`). Paths under generated directories
   (results/) are exempt: they legitimately do not exist in a fresh
   checkout.
2. **Symbol references** (``docs/*.md`` only — the deep guides that rot
   fastest) — every backtick-quoted Python-identifier-looking token
   (``build_halo_plan``, ``HaloPlan``, ``repro.dist.halo`` …) must appear
   somewhere in the source tree (src/, benchmarks/, examples/, tests/,
   tools/), each dotted component checked as a whole word. A renamed or
   deleted symbol fails CI instead of silently rotting the guide.

    python tools/check_docs_refs.py
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
ARCH_DOCS = ("README.md", "DESIGN.md")
GENERATED = ("results/",)
CANDIDATE_ROOTS = ("", "src/repro", "docs", "benchmarks", "examples", "tests", "tools")
SOURCE_DIRS = ("src", "benchmarks", "examples", "tests", "tools")
TOKEN = re.compile(r"[\w.\-/]+\.(?:py|md|yml|yaml|toml|txt|json)\b")
# `code`-quoted tokens that look like Python identifiers or dotted paths
# (pure identifier chars, starting with a letter/underscore, no slashes).
SYMBOL = re.compile(r"`([A-Za-z_][A-Za-z0-9_]*(?:\.[A-Za-z_][A-Za-z0-9_]*)*)`")


def docs() -> list[pathlib.Path]:
    return [ROOT / d for d in ARCH_DOCS] + sorted((ROOT / "docs").glob("*.md"))


def resolves(token: str) -> bool:
    while token.startswith("./"):
        token = token[2:]
    for root in CANDIDATE_ROOTS:
        if (ROOT / root / token).exists():
            return True
    return False


def source_text() -> str:
    chunks = []
    for d in SOURCE_DIRS:
        for path in sorted((ROOT / d).rglob("*.py")):
            chunks.append(path.read_text(encoding="utf-8"))
    return "\n".join(chunks)


def missing_symbols(text: str, src: str) -> list[str]:
    out = []
    for tok in sorted({m.group(1) for m in SYMBOL.finditer(text)}):
        parts = tok.split(".")
        if len(parts) == 1 and len(tok) <= 2:
            continue  # single letters / `k` / `d` math shorthand
        if all(re.search(rf"\b{re.escape(p)}\b", src) for p in parts):
            continue
        out.append(tok)
    return out


def main() -> int:
    failures: list[str] = []
    src = source_text()
    for path in docs():
        doc = path.relative_to(ROOT).as_posix()
        text = path.read_text(encoding="utf-8")
        for tok in sorted({m.group(0) for m in TOKEN.finditer(text)}):
            if tok.startswith(GENERATED):
                continue
            if not resolves(tok):
                failures.append(f"MISSING FILE: {doc} references {tok!r} which does not exist")
        if doc.startswith("docs/"):
            for tok in missing_symbols(text, src):
                failures.append(
                    f"MISSING SYMBOL: {doc} references `{tok}` which appears nowhere in "
                    f"{'/'.join(SOURCE_DIRS)}"
                )
    if failures:
        print("\n".join(failures))
        return 1
    print(f"docs refs OK ({', '.join(p.relative_to(ROOT).as_posix() for p in docs())})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
