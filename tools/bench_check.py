"""Gate fresh BENCH_* artifacts against the pinned baselines.

CI regenerates BENCH_comm.json / BENCH_kernels.json / BENCH_delta.json on
every run (the benches are pinned-seed, so their *accounting* numbers are
deterministic) and this tool compares them against the checked-in copies
under ``benchmarks/baselines/``:

* **deterministic values** (wire bytes, overlap fractions, nnz/executed
  tile counts, remap counts, case configs) must match EXACTLY — any drift
  means the comm/kernels/delta accounting changed and either a bug slipped
  in or the baseline must be consciously re-pinned with the PR;
* **timing values** (``*_ms`` / ``*_s`` leaves) are machine-dependent and
  are skipped;
* **derived speed ratios** (the delta bench's ``speedup``) get a loose
  floor: at least half the baseline ratio AND an absolute minimum, so a
  10× regression fails without flaking on runner noise.

Usage (kind inferred from the file name ``BENCH_<kind>.json``):

    python tools/bench_check.py BENCH_comm.json BENCH_kernels.json BENCH_delta.json
    python tools/bench_check.py BENCH_delta.json --baseline-dir benchmarks/baselines

Exit code 0 = all artifacts within tolerance, 1 = regression (or a
baseline key missing from the fresh artifact).
"""
from __future__ import annotations

import argparse
import json
import math
import os
import re
import sys

BASELINE_DIR = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "baselines")

# Leaf names that are wall-clock measurements: never compared exactly.
TIMING_SUFFIXES = ("_ms", "_s", "_us")

# Per-kind overrides, keyed by the flattened dotted path's LEAF name.
#   ("skip",)                — ignore entirely
#   ("min", floor, frac)     — fresh >= max(floor, baseline * frac)
KIND_RULES = {
    "comm": {},
    "kernels": {},
    "delta": {
        "speedup": ("min", 5.0, 0.5),
    },
    "obs": {},
    "relocal": {
        # The stale-order decay must stay a real effect (>= 2x the fresh
        # reorder); the maintained ceiling (<= 1.15x) is asserted inside
        # benchmarks/relocal_bench.py before the artifact is written.
        "degraded_ratio": ("min", 2.0, 0.5),
        "maintained_ratio": ("skip",),
    },
    "autotune": {
        # The placement/search wins get loose floors (a strictly BETTER
        # search result must not fail the gate); the hard >=1.3x acceptance
        # gates and tiles_ratio <= 1.0 are asserted inside
        # benchmarks/autotune_bench.py before the artifact is written.
        # Objective terms end in _s and are auto-skipped with the timings;
        # the row/byte accounting leaves stay exact.
        "crossing_improvement": ("min", 1.3, 0.9),
        "exposed_improvement": ("min", 1.3, 0.5),
        "predicted_objective_improvement": ("min", 1.0, 0.5),
        "tiles_ratio": ("skip",),
    },
}


def flatten(obj, prefix: str = "") -> dict:
    """``{"a": {"b": [1, 2]}}`` → ``{"a.b.0": 1, "a.b.1": 2}``."""
    out: dict = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(flatten(v, f"{prefix}.{k}" if prefix else str(k)))
    elif isinstance(obj, (list, tuple)):
        for i, v in enumerate(obj):
            out.update(flatten(v, f"{prefix}.{i}" if prefix else str(i)))
    else:
        out[prefix] = obj
    return out


def _is_timing(path: str) -> bool:
    leaf = path.rsplit(".", 1)[-1]
    return leaf.endswith(TIMING_SUFFIXES)


def _values_match(base, fresh) -> bool:
    if isinstance(base, bool) or isinstance(fresh, bool):
        return base == fresh
    if isinstance(base, (int, float)) and isinstance(fresh, (int, float)):
        return math.isclose(float(base), float(fresh), rel_tol=1e-6, abs_tol=1e-9)
    return base == fresh


def check_artifact(fresh_path: str, baseline_path: str, kind: str) -> list[str]:
    """Compare one artifact; returns a list of human-readable violations."""
    with open(fresh_path) as f:
        fresh = flatten(json.load(f))
    with open(baseline_path) as f:
        base = flatten(json.load(f))
    rules = KIND_RULES.get(kind, {})
    errors = []
    for path, bval in sorted(base.items()):
        leaf = path.rsplit(".", 1)[-1]
        rule = rules.get(leaf, rules.get(path))
        if rule and rule[0] == "skip":
            continue
        if path not in fresh:
            errors.append(f"{path}: missing from fresh artifact (baseline={bval!r})")
            continue
        fval = fresh[path]
        if rule and rule[0] == "min":
            _, floor, frac = rule
            need = max(floor, float(bval) * frac)
            if float(fval) < need:
                errors.append(
                    f"{path}: {fval:.3g} below floor {need:.3g} "
                    f"(baseline {float(bval):.3g}, tolerance ×{frac})")
            continue
        if _is_timing(path):
            continue
        if not _values_match(bval, fval):
            errors.append(f"{path}: fresh={fval!r} != baseline={bval!r}")
    return errors


def infer_kind(path: str) -> str:
    m = re.search(r"BENCH_(\w+)\.json$", os.path.basename(path))
    if not m or m.group(1) not in KIND_RULES:
        raise SystemExit(
            f"{path}: cannot infer artifact kind "
            f"(expected BENCH_<{'|'.join(KIND_RULES)}>.json; use --kind)")
    return m.group(1)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("artifacts", nargs="+", help="fresh BENCH_<kind>.json files")
    ap.add_argument("--baseline-dir", default=BASELINE_DIR,
                    help="directory holding the pinned BENCH_<kind>.json copies")
    ap.add_argument("--kind", default=None,
                    help="override the kind inferred from the file name")
    args = ap.parse_args(argv)

    failed = 0
    for path in args.artifacts:
        kind = args.kind or infer_kind(path)
        baseline = os.path.join(args.baseline_dir, f"BENCH_{kind}.json")
        if not os.path.exists(baseline):
            print(f"SKIP {path}: no pinned baseline at {baseline}")
            continue
        errors = check_artifact(path, baseline, kind)
        if errors:
            failed += 1
            print(f"FAIL {path} vs {baseline}:")
            for e in errors:
                print(f"  {e}")
        else:
            print(f"OK   {path} matches {baseline} "
                  f"(timing leaves skipped, ratios within tolerance)")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
