"""Design-space exploration example: the COIN objective at chip scale (the
paper's Fig. 9/19) AND re-targeted to a TPU pod (DESIGN.md §2) — shows how
the same communication-balance criterion picks both the 4×4 CE mesh and the
model-parallel degree for the distributed GCN.

    PYTHONPATH=src python examples/coin_design_space.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core.energy import model_from_gcn
from repro.core.planner import plan_gnn_sharding
from repro.core.solver import SQUARE_MESHES, mesh_sweep, optimal_ce_count
from repro.graph.generators import TABLE_I


def main() -> None:
    print("== chip scale: CE-count sweep (paper Fig. 9) ==")
    for name, spec in TABLE_I.items():
        m = model_from_gcn(spec.n_nodes, [spec.n_features, 16, spec.n_labels], 4)
        sweep = mesh_sweep(m)
        best = min(sweep, key=sweep.get)
        side = int(np.sqrt(best))
        res = optimal_ce_count(m)
        norm = {k: v / max(sweep.values()) for k, v in sweep.items()}
        bar = " ".join(f"{k}:{norm[k]:.2f}" for k in SQUARE_MESHES)
        print(f"  {name:9s} best={side}x{side} k*={res.k_star:5.1f}  E/Emax: {bar}")

    print("\n== pod scale: model-parallel degree via the same objective ==")
    for name, spec in TABLE_I.items():
        for schedule in ("broadcast", "halo"):
            plan = plan_gnn_sharding(
                spec.n_nodes, spec.n_edges, [spec.n_features, 16, spec.n_labels],
                n_devices=256, schedule=schedule,
                cut_fraction=0.3 if schedule == "halo" else None,
            )
            print(f"  {name:9s} [{schedule:9s}] model={plan.model_shards:3d} "
                  f"data={plan.data_shards:3d} est_step={plan.est_step_s*1e6:7.1f}µs "
                  f"dominant={plan.dominant}")


if __name__ == "__main__":
    main()
