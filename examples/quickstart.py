"""Quickstart: the COIN methodology end-to-end on a Cora-statistics graph.

    PYTHONPATH=src python examples/quickstart.py

1. synthesize a graph with Cora's published shape (2708 nodes / 10556 edges),
2. find the optimal CE count with the paper's interior-point solver (→ 4×4),
3. partition the graph onto the CEs and measure connection probabilities,
4. push the layer-exchange traffic through the mesh-NoC model (energy/latency),
5. train the paper's 2-layer GCN with the COIN feature-first dataflow and
   4-bit quantization, and report accuracy.
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core.energy import CoinEnergyModel
from repro.core.noc import MeshNoC
from repro.core.partition import measured_probabilities, partition_graph
from repro.core.quant import QuantConfig
from repro.core.solver import optimal_ce_count
from repro.graph.generators import make_dataset
from repro.graph.structure import to_padded
from repro.models.gcn import GCNConfig, gcn_forward, gcn_init, gcn_loss
from repro.train.optimizer import adam


def main() -> None:
    spec, g = make_dataset("cora")
    print(f"[1] dataset: {spec.name}: N={spec.n_nodes} E={spec.n_edges} "
          f"F={spec.n_features} C={spec.n_labels}")

    # ---- optimal CE count (paper §IV-B)
    part16 = partition_graph(g.n_nodes, g.edge_index, 16, method="bfs", seed=0, refine=True)
    p1, p2 = measured_probabilities(part16)
    model = CoinEnergyModel(
        n_nodes=g.n_nodes, act_bits_sum=spec.hidden * 4,
        p_intra=float(p1.mean()), p_inter=float(p2.sum() / (16 * 15)),
    )
    res = optimal_ce_count(model)
    print(f"[2] optimal CEs: k*={res.k_star:.1f} → {res.mesh_shape[0]}×{res.mesh_shape[1]} mesh "
          f"(solve {res.solve_ms:.1f} ms; paper: 4×4, 10 ms)")

    # ---- NoC energy for the layer exchange (paper Fig. 5c) on the chosen mesh
    part = partition_graph(g.n_nodes, g.edge_index, res.k_mesh, method="bfs", seed=0, refine=True)
    noc = MeshNoC(*res.mesh_shape)
    traffic = part.inter_ce_traffic_bits(spec.hidden * 4, broadcast=True)
    s = noc.summarize(traffic)
    halo = noc.summarize(part.inter_ce_traffic_bits(spec.hidden * 4, broadcast=False))
    print(f"[3] inter-CE exchange: {s.total_bits/8e3:.1f} kB, {s.latency_cycles:.0f} cycles "
          f"(beyond-paper halo: {halo.total_bits/8e3:.1f} kB)")

    # ---- train the paper's GCN (feature-first dataflow, 4-bit QAT)
    gs = g.symmetrized().with_self_loops()
    pg = to_padded(gs, weights=gs.sym_normalized_weights())
    cfg = GCNConfig(
        layer_dims=(spec.n_features, spec.hidden, spec.n_labels),
        dataflow="auto",
        quant=QuantConfig(4, 4, enabled=True),
    )
    params = gcn_init(jax.random.PRNGKey(0), cfg)
    feats = jnp.asarray(g.features, jnp.float32)
    labels = jnp.asarray(g.labels)
    mask = (jnp.arange(spec.n_nodes) % 4 != 0).astype(jnp.float32)
    opt = adam(1e-2)
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        loss, grads = jax.value_and_grad(gcn_loss)(
            params, feats, pg.senders, pg.receivers, pg.edge_weight, labels, mask, cfg
        )
        params, state = opt.update(grads, state, params)
        return params, state, loss

    for epoch in range(100):
        params, state, loss = step(params, state)
        if epoch % 25 == 0:
            print(f"    epoch {epoch:3d}: loss={float(loss):.4f}")
    logits = gcn_forward(params, feats, pg.senders, pg.receivers, pg.edge_weight, cfg)
    test = 1.0 - mask
    acc = float(((jnp.argmax(logits, -1) == labels) * test).sum() / test.sum())
    print(f"[4] 4-bit GCN test accuracy: {acc:.3f} "
          f"(dataflow order: {cfg.dataflow} → feature-first, §IV-C3)")


if __name__ == "__main__":
    main()
