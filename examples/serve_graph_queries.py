"""Online GCN query serving demo (DESIGN.md §9).

Builds a citation-like graph, starts a `repro.serve.graph.GraphBatcher` with
the hot-neighbor cache, and serves a hub-heavy query stream in arrival
waves — mixed live sizes per micro-batch, one compiled forward throughout.
Prints the latency percentiles and the cache accounting, then demonstrates
invalidation: a weight update flushes the cache and the next wave re-warms it.

    PYTHONPATH=src python examples/serve_graph_queries.py
"""
import jax
import numpy as np

from repro.configs import get_arch
from repro.launch.serve import build_graph_engine
from repro.models.gcn import gcn_init
from repro.serve.graph import hot_query_stream


def main() -> None:
    spec = get_arch("coin_gcn")
    engine, graph = build_graph_engine(spec, cache_capacity=256, n_parts=4, seed=0)

    for wave, size in enumerate((16, 7, 16, 3, 16)):     # mixed arrival sizes
        for v in hot_query_stream(graph, size, seed=wave):
            engine.submit(int(v))
        engine.run_until_drained()
        c = engine.cache.stats()
        print(f"wave {wave}: {size:3d} queries  hit-rate {c['hit_rate']:.1%}  "
              f"resident {c['resident']}/{c['capacity']}")

    s = engine.stats()
    print(f"\nserved {s['queries']} queries in {s['micro_batches']} micro-batches, "
          f"{s['traces']} trace (compile-once)")
    print(f"latency p50={s['p50_ms']:.2f} ms p99={s['p99_ms']:.2f} ms | "
          f"{s['nodes_per_query']:.1f} nodes/q {s['edges_per_query']:.1f} edges/q")
    c = s["cache"]
    print(f"hot-neighbor cache: {c['hits']} hits / {c['misses']} misses, "
          f"rows saved {c['rows_saved']}, bytes saved {c['bytes_saved']/1e3:.1f} kB")

    # A weight push invalidates every cached activation (they are pure
    # functions of params+features), then the next wave re-warms.
    engine.update_params(gcn_init(jax.random.PRNGKey(42), engine.cfg))
    print(f"\nweight update → cache flushed (resident {len(engine.cache)}), "
          f"invalidations={engine.cache.invalidations}")
    for v in hot_query_stream(graph, 16, seed=99):
        engine.submit(int(v))
    engine.run_until_drained()
    print(f"post-update wave: resident {len(engine.cache)}, "
          f"traces still {engine.stats()['traces']} (no retrace)")


if __name__ == "__main__":
    main()
