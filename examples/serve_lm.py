"""Serving example (deliverable b): batched prefill + KV-cache decode with a
reduced gemma3-style sliding-window LM — the serve path the decode_32k /
long_500k dry-run cells lower at production scale.

    PYTHONPATH=src python examples/serve_lm.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models.transformer_lm import (
    lm_decode_step,
    lm_forward,
    lm_init,
    lm_init_cache,
)


def main() -> None:
    cfg = get_arch("gemma3-12b").make_reduced()
    print(f"model: {cfg.name} ({cfg.n_layers}L, window={cfg.window}, "
          f"global every {cfg.global_every})")
    params = lm_init(jax.random.PRNGKey(0), cfg)

    batch, prompt_len, gen_len, max_len = 4, 24, 16, 64
    prompts = jax.random.randint(jax.random.PRNGKey(1), (batch, prompt_len), 0, cfg.vocab)

    # ---- prefill: run the prompt once, fill the cache via decode steps
    # (teacher-forced so decode == forward is also checked here).
    cache = lm_init_cache(cfg, batch, max_len)
    decode = jax.jit(lm_decode_step, static_argnames=("cfg",))
    logits = None
    t0 = time.perf_counter()
    for t in range(prompt_len):
        logits, cache = decode(params, cache, prompts[:, t], jnp.asarray(t, jnp.int32), cfg)
    prefill_s = time.perf_counter() - t0
    ref, _ = lm_forward(params, prompts, cfg)
    err = float(jnp.abs(logits - ref[:, -1]).max())
    print(f"prefill {prompt_len} tokens in {prefill_s*1e3:.1f} ms; "
          f"decode-vs-forward max err {err:.2e}")

    # ---- greedy decode
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for t in range(prompt_len, prompt_len + gen_len):
        logits, cache = decode(params, cache, tok, jnp.asarray(t, jnp.int32), cfg)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    dt = time.perf_counter() - t0
    gen = jnp.stack(out, 1)
    print(f"generated {gen_len} tokens × {batch} streams in {dt*1e3:.1f} ms "
          f"({batch * gen_len / dt:.0f} tok/s on CPU)")
    print("sample token ids:", gen[0, :10].tolist())


if __name__ == "__main__":
    main()
