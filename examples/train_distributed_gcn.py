"""Distributed full-graph GCN training over the DEFAULT halo comm path.

Demonstrates the PR-2 communication stack end to end (DESIGN.md §8): a
Cora-stats synthetic graph is partitioned across every visible device
(BFS + refinement, the locality lever that keeps export sets small), the
cached `HaloPlan` relocates it into blocked per-device layout, and each GCN
layer's aggregation exchanges only boundary rows via
`policy.neighbor_table` inside `shard_map` — `k·s_max` received rows per
device instead of the broadcast schedule's `(k−1)·n_local`. Training runs
on the production substrate (`Trainer`: jitted step, checkpointing,
straggler monitor) and prints the plan-cache hit count: one relocation
serves every layer of every step.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    PYTHONPATH=src python examples/train_distributed_gcn.py [--steps 60]

Runs on any device count (including 1, where the halo degenerates to an
empty exchange). ``--pods 2`` switches to the hierarchical (pod, model)
schedule (docs/communication.md): the mesh becomes 2-D, the plan splits
each device's boundary set into intra-/inter-pod tiers, and the exchange
runs in two phases — the printout shows how few rows cross the expensive
inter-pod fabric vs the flat plan.

``--trace out.json`` / ``--metrics out.json`` (docs/observability.md) turn
on the `repro.obs` telemetry: the metrics snapshot mirrors the plan's wire
accounting and cache stats, and the trace ends with an `overlap_timeline`
demo where the ``halo.exchange.boundary_collective`` span on the ``wire``
track visibly encloses ``overlap.interior_compute`` in Perfetto.
"""
import argparse
import sys
import tempfile

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.partition import partition_graph
from repro.dist.halo import (
    get_halo_plan,
    node_mask,
    plan_cache_stats,
    relocate_node_array,
    restore_node_array,
)
from repro.dist.policy import ShardingPolicy
from repro.graph.generators import make_dataset
from repro.launch.obsflags import add_obs_args, obs_session
from repro.models.gcn import GCNConfig, gcn_forward, gcn_init
from repro.obs import metrics as obs_metrics, trace as obs_trace
from repro.train.loop import Trainer, TrainerConfig
from repro.train.optimizer import adamw


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--pods", type=int, default=1,
                    help="pods for the hierarchical (pod, model) halo schedule "
                         "(must divide the device count; 1 = flat single-axis)")
    add_obs_args(ap)
    args = ap.parse_args()
    with obs_session(args):
        run(args)


def run(args) -> None:
    k = jax.device_count()
    pods = args.pods
    if pods < 1 or k % pods:
        raise SystemExit(f"--pods {pods} must divide the device count {k}")
    hier = pods > 1
    if hier:
        axes = ("pod", "model")
        mesh = jax.make_mesh((pods, k // pods), axes)
        print(f"devices: {k} (mesh {pods}×{k // pods}, axes {axes})")
    else:
        axes = ("model",)
        mesh = jax.make_mesh((k,), axes)
        print(f"devices: {k} (mesh axis 'model')")

    # ---- graph → partition → cached halo plan --------------------------------
    spec, g = make_dataset("cora", reduced=True)
    gs = g.symmetrized().with_self_loops()
    w = gs.sym_normalized_weights()
    part = partition_graph(gs.n_nodes, gs.edge_index, k, method="bfs", seed=0, refine=True)
    pods_kw = {"pods": pods} if hier else {}
    plan = get_halo_plan(part, gs.edge_index, w, **pods_kw)   # miss: builds the relocation
    plan = get_halo_plan(part, gs.edge_index, w, **pods_kw)   # hit: every reuse is free
    print(
        f"graph: {spec.name} n={gs.n_nodes} e={gs.n_edges} → k={plan.k} "
        f"n_local={plan.n_local} "
        + (f"s_loc={plan.s_loc} s_rem={plan.s_rem}" if hier else f"s_max={plan.s_max}")
    )
    if plan.k > 1:
        print(
            f"wire/device/layer: halo {plan.halo_rows_per_device} rows vs "
            f"broadcast {plan.broadcast_rows_per_device} rows "
            f"({plan.wire_fraction():.3f}× — DESIGN.md §8)"
        )
    if hier:
        print(
            f"inter-pod crossing/device/layer: {plan.inter_pod_rows_crossing} rows "
            f"hierarchical vs {plan.flat_inter_pod_rows_crossing} flat "
            "(docs/communication.md)"
        )

    # ---- blocked batch (static across steps: full-graph training) ------------
    if hier:
        sloc, srem, sl, rl, ew = plan.device_arrays()
        send = {"send_loc": sloc, "send_rem": srem}
    else:
        si, sl, rl, ew = plan.device_arrays()
        send = {"send_idx": si}
    batch = {
        "feats": jnp.asarray(relocate_node_array(plan, g.features.astype(np.float32))),
        "labels": jnp.asarray(relocate_node_array(plan, g.labels.astype(np.int32))),
        "mask": jnp.asarray(node_mask(plan)),
        **send, "senders": sl, "receivers": rl, "edge_w": ew,
    }
    keys = sorted(batch)
    spec_axes = axes if hier else "model"

    cfg = GCNConfig(layer_dims=(spec.n_features, spec.hidden, spec.n_labels))
    params = gcn_init(jax.random.PRNGKey(0), cfg)
    policy = ShardingPolicy(comm="halo", halo_axes=axes if hier else None)

    def bind(b):
        if hier:
            return policy.bind_halo(send_loc=b["send_loc"], send_rem=b["send_rem"])
        return policy.bind_halo(b["send_idx"])

    def loss_fn(params, batch):
        def body(*args):
            b = {kk: a[0] for kk, a in zip(keys, args)}
            pol = bind(b)
            logits = gcn_forward(
                params, b["feats"], b["senders"], b["receivers"], b["edge_w"], cfg, pol
            ).astype(jnp.float32)
            lse = jax.scipy.special.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, b["labels"][:, None], axis=-1)[:, 0]
            wsum = ((lse - gold) * b["mask"]).sum()
            wcnt = b["mask"].sum()
            loss = jax.lax.psum(wsum, spec_axes) / jnp.maximum(
                jax.lax.psum(wcnt, spec_axes), 1.0
            )
            return loss[None]

        f = jax.shard_map(
            body, mesh=mesh,
            in_specs=(P(spec_axes),) * len(keys), out_specs=P(spec_axes),
            check_vma=False,
        )
        return f(*[batch[kk] for kk in keys]).mean()

    # ---- production substrate: Trainer (jit step, ckpt, straggler monitor) ---
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="coin_ckpt_")
    tr = Trainer(
        loss_fn, adamw(1e-2), params,
        TrainerConfig(ckpt_dir=ckpt_dir, ckpt_every=50, log_every=20),
    )
    resumed = tr.resume()
    print(f"checkpoints → {ckpt_dir} (resumed={resumed}, step={tr.step})")
    losses = tr.fit(iter(lambda: batch, None), max_steps=args.steps)

    # ---- evaluate through the same halo path ---------------------------------
    def fwd(batch):
        def body(*args):
            b = {kk: a[0] for kk, a in zip(keys, args)}
            pol = bind(b)
            return gcn_forward(
                tr.params, b["feats"], b["senders"], b["receivers"], b["edge_w"], cfg, pol
            )[None]

        f = jax.shard_map(
            body, mesh=mesh,
            in_specs=(P(spec_axes),) * len(keys), out_specs=P(spec_axes),
            check_vma=False,
        )
        return f(*[batch[kk] for kk in keys])

    logits = restore_node_array(plan, np.asarray(fwd(batch)))
    acc = float((logits.argmax(-1) == g.labels).mean())
    stats = plan_cache_stats()
    print(f"done: step={tr.step} loss {losses[0]:.4f} → {losses[-1]:.4f} acc={acc:.3f}; "
          f"stragglers observed: {len(tr.straggler_events)}")
    print(f"plan cache: {stats['hits']} hits / {stats['misses']} misses "
          f"({stats['size']} cached) — one relocation serves all layers/steps")
    assert losses[-1] < losses[0], "training must make progress"
    assert stats["hits"] >= 1 and stats["misses"] >= 1

    # ---- telemetry: mirror the accounting, then trace the overlap ------------
    if obs_metrics.enabled():
        from repro.obs.instrument import observe_plan_cache, record_exchange

        record_exchange(plan, int(batch["feats"].shape[-1]))
        observe_plan_cache()
    tracer = obs_trace.default_tracer()
    if tracer is not None:
        from repro.obs.instrument import overlap_timeline

        print("tracing overlap: boundary collective (wire track) vs interior compute")
        overlap_timeline(plan, batch["feats"], mesh, tracer=tracer)


if __name__ == "__main__":
    main()
