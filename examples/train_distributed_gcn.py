"""End-to-end training driver (deliverable b): train a ~100M-param GraphCast
on synthetic data for a few hundred steps with the full production substrate —
Trainer (jit step, checkpointing, straggler monitor), AdamW, gradient
compression, crash + resume.

    PYTHONPATH=src python examples/train_distributed_gcn.py [--steps 300]

~100M params: GraphCast d_hidden=512, 16 layers → ≈ 102M weights. On CPU this
runs a reduced width by default; pass --full for the real 100M config.
"""
import argparse
import sys
import tempfile

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.generators import citation_like
from repro.models.graphcast import GraphCastConfig, graphcast_init, graphcast_loss
from repro.train.loop import Trainer, TrainerConfig
from repro.train.optimizer import adamw


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full", action="store_true", help="use the real ~100M config")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = (
        GraphCastConfig(n_layers=16, d_hidden=512, n_vars=64, d_in=64)
        if args.full
        else GraphCastConfig(n_layers=4, d_hidden=96, n_vars=32, d_in=32)
    )
    params = graphcast_init(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))
    print(f"model: graphcast {cfg.n_layers}L d={cfg.d_hidden} → {n_params/1e6:.1f}M params")

    g = citation_like(2048, 16384, seed=0)
    senders = jnp.asarray(g.edge_index[0])
    receivers = jnp.asarray(g.edge_index[1])
    edge_feats = jnp.asarray(
        np.random.default_rng(0).standard_normal((g.n_edges, cfg.d_edge_in)), jnp.float32
    )

    def loss_fn(params, batch):
        return graphcast_loss(
            params, batch["x"], edge_feats, senders, receivers, batch["y"], cfg
        )

    rng = np.random.default_rng(1)

    def batches():
        while True:
            x = jnp.asarray(rng.standard_normal((g.n_nodes, cfg.input_dim)), jnp.float32)
            # Learnable synthetic target: smooth function of the input.
            y = jnp.tanh(x @ jnp.ones((cfg.input_dim, cfg.n_vars)) * 0.1)
            yield {"x": x, "y": y}

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="coin_ckpt_")
    tr = Trainer(
        loss_fn,
        adamw(3e-4),
        params,
        TrainerConfig(ckpt_dir=ckpt_dir, ckpt_every=50, log_every=25, compress_grads=True),
    )
    resumed = tr.resume()
    print(f"checkpoints → {ckpt_dir} (resumed={resumed}, step={tr.step})")
    losses = tr.fit(batches(), max_steps=args.steps)
    print(f"done: step={tr.step} loss {losses[0]:.4f} → {losses[-1]:.4f}; "
          f"stragglers observed: {len(tr.straggler_events)}")
    assert losses[-1] < losses[0], "training must make progress"


if __name__ == "__main__":
    main()
